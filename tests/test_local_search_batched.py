"""Batched local-search engine: scored move matrices vs scalar probing.

Three contracts pin the PR-3 engine:

* `score_moves_batch` agrees with sequential `_try_move`-style probing —
  the same destinations are admissible, with the same commit caps and the
  same post-move objectives at 1e-9 — checked here deterministically on
  the fixed instance suite and property-based (random instances) in
  tests/test_score_moves_property.py;
* batched relocate+consolidate never ends at a worse objective than the
  reference first-improvement path from the same construction state, and
  the full batched AGH never returns a worse objective than
  `local_search="reference"`;
* every solution the batched engine returns passes the full constraint
  system (`is_feasible`), and the parallel multi-start driver returns the
  identical solution for any worker count.
"""
import numpy as np
import pytest

from repro.core import agh, default_instance, gh, objective, random_instance
from repro.core.agh import (_consolidate, _consolidate_batched, _orderings,
                            _rank_inactive_targets, _relocate,
                            _relocate_batched)
from repro.core.gh import _phase1, greedy_heuristic
from repro.core.mechanisms import (State, commit, max_commit,
                                   remove_assignment, score_moves_batch,
                                   state_objective, state_snapshot,
                                   undo_all)
from repro.core.solution import is_feasible


def probe_all_destinations(state: State, i: int, j: int, k: int):
    """Sequential `_try_move`-style probe of every destination: returns
    (frac, {(j2,k2): (admissible, cap, obj_after)}) with the state left
    exactly as found.  This is the scalar oracle the scored matrix must
    reproduce."""
    inst = state.inst
    undo: list = []
    frac = remove_assignment(state, i, j, k, undo=undo)
    out = {}
    for j2 in range(inst.J):
        for k2 in range(inst.K):
            if (j2, k2) == (j, k):
                continue
            if state.q[j2, k2] > 0.5:
                c = int(state.cfg[j2, k2])
                if inst.D_cfg[i, j2, k2, c] > inst.Delta[i]:
                    out[(j2, k2)] = (False, None, None)
                    continue
            else:
                c = int(inst.cfg_m1[i, j2, k2])
                if c < 0:
                    out[(j2, k2)] = (False, None, None)
                    continue
            cap = max_commit(state, i, j2, k2, c)
            if cap < frac - 1e-9:
                out[(j2, k2)] = (False, cap, None)
                continue
            u2: list = []
            commit(state, i, j2, k2, c, frac, undo=u2)
            out[(j2, k2)] = (True, cap, state_objective(state))
            undo_all(state, u2)
    undo_all(state, undo)
    return frac, out


def assert_scores_match_probing(state: State, i: int, j: int, k: int):
    """Shared oracle comparison (also driven by the hypothesis suite)."""
    before = state_snapshot(state)
    frac, probed = probe_all_destinations(state, i, j, k)
    ms = score_moves_batch(state, i, j, k)
    assert abs(ms.frac - frac) <= 1e-12
    for (j2, k2), (adm, cap, obj) in probed.items():
        assert bool(ms.admissible[j2, k2]) == adm, (i, j, k, j2, k2)
        if cap is not None:
            assert abs(ms.caps[j2, k2] - cap) <= 1e-9 * max(1.0, cap), \
                (i, j, k, j2, k2)
        if adm:
            assert abs(ms.obj_after[j2, k2] - obj) \
                <= 1e-9 * max(1.0, abs(obj)), (i, j, k, j2, k2)
    # the scan must leave the state untouched
    for a, b in zip(before, state_snapshot(state), strict=True):
        if isinstance(a, (set, float)):
            assert a == b
        else:
            assert np.array_equal(a, b)


def sources_of(state: State):
    return [(int(i), int(f) // state.inst.K, int(f) % state.inst.K)
            for i in range(state.inst.I)
            for f in np.flatnonzero((state.x[i] > 1e-9).ravel())]


def _ls_instances():
    return [
        ("default", default_instance()),
        ("random-6-6-10", random_instance(6, 6, 10, seed=1)),
        ("random-8-5-6", random_instance(8, 5, 6, seed=2)),
        ("random-10-10-10", random_instance(10, 10, 10, seed=3)),
        ("stressed-1.15", default_instance().stressed(1.15)),
        ("tight-budget", random_instance(6, 6, 10, seed=4, budget=40.0)),
        ("random-15-15-10", random_instance(15, 15, 10, seed=7)),
    ]


@pytest.mark.parametrize("name,inst", _ls_instances())
def test_score_moves_batch_matches_probing_on_suite(name, inst):
    _, state = greedy_heuristic(inst)
    srcs = sources_of(state)
    assert srcs, name
    for (i, j, k) in srcs[:10]:
        assert_scores_match_probing(state, i, j, k)


@pytest.mark.parametrize("name,inst", _ls_instances()[:4])
def test_score_moves_batch_improve_below_filter_on_suite(name, inst):
    """The lazy `improve_below` path (including its scalar-caps branch for
    few surviving candidates) is exactly the full scan filtered by the
    improvement bound."""
    _, state = greedy_heuristic(inst)
    obj = state_objective(state)
    for (i, j, k) in sources_of(state)[:8]:
        full = score_moves_batch(state, i, j, k)
        lazy = score_moves_batch(state, i, j, k, improve_below=obj - 1e-9)
        want = full.admissible & (full.obj_after < obj - 1e-9)
        assert np.array_equal(lazy.admissible, want), name
        assert np.allclose(lazy.obj_after[want], full.obj_after[want],
                           atol=0, rtol=1e-12)


@pytest.mark.parametrize("name,inst", _ls_instances())
def test_batched_relocate_never_worse_per_ordering(name, inst):
    """From every multi-start construction state, the batched engine ends
    at an objective <= the reference first-improvement path's (it scores
    the full destination grid, a superset of the reference shortlist, and
    applies the best admissible move)."""
    st0 = State.fresh(inst)
    _phase1(st0)
    p1 = state_snapshot(st0)
    ranked = _rank_inactive_targets(inst)
    rng = np.random.default_rng(0)
    for n, order in enumerate(_orderings(inst, 3, rng)):
        _, stb = greedy_heuristic(inst, order=order, phase1_snapshot=p1)
        _relocate_batched(stb, 3, False)
        _consolidate_batched(stb, False)
        _, str_ = greedy_heuristic(inst, order=order, phase1_snapshot=p1)
        _relocate(str_, 3, ranked, False)
        _consolidate(str_, False)
        ob, orf = state_objective(stb), state_objective(str_)
        assert ob <= orf + 1e-9, (name, n, ob, orf)


@pytest.mark.parametrize("name,inst", _ls_instances())
def test_batched_agh_never_worse_and_feasible(name, inst):
    sol_b = agh(inst, validate=True)
    sol_r = agh(inst, local_search="reference")
    assert is_feasible(inst, sol_b, enforce_zeta=False), name
    ob, orf = objective(inst, sol_b), objective(inst, sol_r)
    assert ob <= orf + 1e-9, (name, ob, orf)
    # and never worse than plain GH
    assert ob <= objective(inst, gh(inst)) + 1e-9, name


def test_parallel_multi_start_worker_count_invariant():
    """The deterministic-reduction protocol returns the identical solution
    for any worker count (inline, 2 procs, 3 procs) given the same seed."""
    inst = random_instance(15, 15, 10, seed=9)
    sols = [agh(inst, workers=w) for w in (1, 2, 3)]
    for s in sols[1:]:
        for field in ("x", "y", "q", "z", "w", "u"):
            assert np.array_equal(getattr(s, field), getattr(sols[0], field))
    # and it is never worse than the sequential early-stop protocol, which
    # evaluates a prefix of the same orderings
    seq = agh(inst, workers=0)
    assert objective(inst, sols[0]) <= objective(inst, seq) + 1e-9


def test_parallel_multi_start_matches_inline_on_default():
    inst = default_instance()
    par = agh(inst, workers=2)
    inline = agh(inst, workers=1)
    for field in ("x", "y", "q", "z", "w", "u"):
        assert np.array_equal(getattr(par, field), getattr(inline, field))
