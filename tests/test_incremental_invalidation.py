"""Incremental local-search engine: caches, dirty-source marks, and the
fallback-rescan convergence guarantee (PR 4).

Five contracts pin the incremental engine:

* the `DestCache` rows feed the exact scoring path bit-identically to the
  uncached rebuild, across applied moves, drains, and deactivations (the
  diff-sync / lazy-build machinery can never go stale);
* the pure scan (`cache` + `improve_below`) selects exactly the move the
  exhaustive scan's argmin would select — same destination cell, same
  config — or correctly reports that no admissible improving move exists;
* dirty-source AGH reaches a converged state in which a full rescan finds
  no improving relocate move and no drainable pair — the "no improving
  move is ever missed" guarantee of the fallback verification rescan;
* incremental and always-rescan batched AGH end at bit-equal objectives
  on every fixed equivalence instance (the dirty marks change *when*
  moves are found, never *which* fixed point quality is reached);
* `deactivate_pair` undo records restore the state bitwise, and the
  `over=` scalar overrides reproduce the plain cap paths exactly.
"""
import numpy as np
import pytest

from repro.core import agh, default_instance, objective, random_instance
from repro.core.agh import _improve_batched, _try_drain_batched
from repro.core.gh import greedy_heuristic
from repro.core.mechanisms import (DestCache, State, deactivate_pair,
                                   max_commit, max_commit_batch,
                                   max_commit_cells, score_moves_batch,
                                   state_objective, state_snapshot,
                                   undo_all)
from repro.core.solution import is_feasible


def _suite():
    return [
        ("default", default_instance()),
        ("random-6-6-10", random_instance(6, 6, 10, seed=1)),
        ("random-8-5-6", random_instance(8, 5, 6, seed=2)),
        ("random-10-10-10", random_instance(10, 10, 10, seed=3)),
        ("stressed-1.15", default_instance().stressed(1.15)),
        ("tight-budget", random_instance(6, 6, 10, seed=4, budget=40.0)),
        ("random-15-15-10", random_instance(15, 15, 10, seed=7)),
    ]


def sources_of(st: State):
    return [(int(i), int(f) // st.inst.K, int(f) % st.inst.K)
            for i in range(st.inst.I)
            for f in np.flatnonzero((st.x[i] > 1e-9).ravel())]


def _assert_states_equal(snap_a, snap_b):
    for a, b in zip(snap_a, snap_b, strict=True):
        if isinstance(a, (set, float)):
            assert a == b
        else:
            assert np.array_equal(a, b)


@pytest.mark.parametrize("name,inst", _suite())
def test_cached_exact_scan_bitwise_matches_uncached(name, inst):
    """The cache-backed exact path (no improve_below) must produce the
    same scores as the uncached rebuild — bitwise, since the rows hold
    the same values — including after moves and drains mutate the state
    under the cache's feet."""
    _, st = greedy_heuristic(inst)
    cache = DestCache(st)
    srcs = sources_of(st)
    assert srcs, name
    for (i, j, k) in srcs[:8]:
        plain = score_moves_batch(st, i, j, k)
        cached = score_moves_batch(st, i, j, k, cache=cache)
        assert np.array_equal(plain.admissible, cached.admissible)
        assert np.array_equal(plain.caps, cached.caps)
        assert np.array_equal(plain.obj_after, cached.obj_after)
        assert plain.obj_removed == cached.obj_removed
    # Disturb the state through the real engine (moves, drains,
    # deactivations), then re-compare: the diff-sync must keep up.
    _improve_batched(st, 3, False)
    cache2 = DestCache(st)
    for (i, j, k) in sources_of(st)[:8]:
        plain = score_moves_batch(st, i, j, k)
        cached = score_moves_batch(st, i, j, k, cache=cache2)
        assert np.array_equal(plain.obj_after, cached.obj_after), name


@pytest.mark.parametrize("name,inst", _suite())
def test_pure_scan_selects_exhaustive_argmin(name, inst):
    """The pure (cache + improve_below) scan is lazy — it reports only
    the best admissible destination — but that destination must be
    exactly the argmin of the exhaustive scan's improving admissible
    set, and its absence must mean the exhaustive set is empty."""
    _, st = greedy_heuristic(inst)
    cache = DestCache(st)
    obj = state_objective(st)
    before = state_snapshot(st)
    checked_found = checked_empty = 0
    for (i, j, k) in sources_of(st):
        full = score_moves_batch(st, i, j, k)
        lazy = score_moves_batch(st, i, j, k, improve_below=obj - 1e-9,
                                 cache=cache, obj_cur=obj)
        want = full.admissible & (full.obj_after < obj - 1e-9)
        if lazy.admissible.any():
            sel = int(np.argmax(lazy.admissible.ravel()))
            masked = np.where(want, full.obj_after, np.inf)
            assert want.ravel()[sel], (name, i, j, k)
            assert sel == int(np.argmin(masked)), (name, i, j, k)
            assert abs(lazy.obj_after.ravel()[sel]
                       - full.obj_after.ravel()[sel]) \
                <= 1e-9 * max(1.0, abs(obj)), (name, i, j, k)
            checked_found += 1
        else:
            assert not want.any(), (name, i, j, k)
            checked_empty += 1
    # the scans must leave the state untouched
    _assert_states_equal(before, state_snapshot(st))
    assert checked_found + checked_empty > 0, name


@pytest.mark.parametrize("name,inst", _suite())
def test_incremental_converges_to_verified_fixed_point(name, inst):
    """After `_improve_batched` with dirty-source tracking, a full rescan
    must find no improving relocate move for any source and no drainable
    pair — i.e. the approximate invalidation rule deferred moves but the
    verification rescan guaranteed none was missed."""
    _, st = greedy_heuristic(inst)
    _improve_batched(st, 3, False, incremental=True)
    obj = state_objective(st)
    for (i, j, k) in sources_of(st):
        ms = score_moves_batch(st, i, j, k, improve_below=obj - 1e-9)
        assert not ms.admissible.any(), (name, i, j, k)
    for f in np.flatnonzero((st.q > 0.5).ravel()):
        j, k = int(f) // inst.K, int(f) % inst.K
        assert _try_drain_batched(st, j, k, False) is None, (name, j, k)


@pytest.mark.parametrize("name,inst", _suite())
def test_incremental_bit_equal_to_always_rescan(name, inst):
    """Full AGH: the incremental engine and the always-rescan engine must
    end at bit-equal objectives on the fixed equivalence suite (and both
    feasible, and never worse than reference mode)."""
    sol_inc = agh(inst, local_search="batched")
    sol_res = agh(inst, local_search="batched-rescan")
    oi, orr = objective(inst, sol_inc), objective(inst, sol_res)
    assert oi == orr, (name, oi, orr)
    assert is_feasible(inst, sol_inc, enforce_zeta=False), name
    sol_ref = agh(inst, local_search="reference")
    assert oi <= objective(inst, sol_ref) + 1e-9, name


@pytest.mark.parametrize("name,inst", _suite()[:4])
def test_per_ordering_incremental_matches_rescan(name, inst):
    """Per construction state, improvement with and without dirty-source
    tracking must land on bit-equal objectives (the tracked run may apply
    moves in a different order, but the verified fixed point it reaches
    scores identically on these instances)."""
    for seed in (0, 1):
        order = np.random.default_rng(seed).permutation(inst.I)
        _, st_a = greedy_heuristic(inst, order=order)
        _improve_batched(st_a, 3, False, incremental=True)
        _, st_b = greedy_heuristic(inst, order=order)
        _improve_batched(st_b, 3, False, incremental=False)
        assert state_objective(st_a) == state_objective(st_b), (name, seed)


@pytest.mark.parametrize("seed", range(8))
def test_cache_coherent_after_trafficless_drain(seed):
    """A successful drain must arm the cache's config diff even when the
    drained pair carried no routed traffic (empty moved-type set) — the
    cache may never keep scoring a deactivated pair as an active,
    rental-free destination (regression test)."""
    from repro.core.agh import _consolidate_batched, _relocate_batched
    inst = random_instance(12, 12, 10, seed=seed)
    _, st = greedy_heuristic(inst)
    cache = DestCache(st)
    clean: set = set()
    _relocate_batched(st, 3, False, cache, clean, fallback=False)
    _consolidate_batched(st, False, cache, clean)
    assert cache.cfg_dirty or np.array_equal(cache.cfg_seen, st.cfg)
    if st.x.sum() > 0:
        i = int(np.argmax(st.x.sum(axis=(1, 2))))
        cache.rows(st, i)
        assert np.array_equal(cache.cfg_seen, st.cfg), seed


def test_deactivate_pair_undo_is_bitwise_exact():
    inst = random_instance(8, 5, 6, seed=2)
    _, st = greedy_heuristic(inst)
    active = np.argwhere(st.q > 0.5)
    assert active.size
    for (j, k) in active[:4]:
        j, k = int(j), int(k)
        before = state_snapshot(st)
        undo: list = []
        deactivate_pair(st, j, k, undo=undo)
        assert st.q[j, k] == 0.0 and st.cfg[j, k] == -1
        undo_all(st, undo)
        _assert_states_equal(before, state_snapshot(st))


@pytest.mark.parametrize("name,inst", _suite()[:4])
def test_over_scalars_reproduce_plain_cap_paths(name, inst):
    """`max_commit(..., over=state scalars)` and `max_commit_cells` must
    equal the plain scalar/batch evaluations bitwise."""
    _, st = greedy_heuristic(inst)
    J, K = inst.J, inst.K
    for i in range(0, inst.I, max(1, inst.I // 4)):
        over = (float(st.r_rem[i]), st.E_used[i], st.D_used[i],
                st.stor_used[i], st.spend)
        c_arr = np.where(st.q > 0.5, st.cfg, inst.cfg_m1[i])
        caps = max_commit_batch(st, i, c_arr)
        cells = np.flatnonzero((c_arr >= 0).ravel())
        from repro.core.mechanisms import delay_sel
        d_sel = delay_sel(inst, i, c_arr)
        caps_c = max_commit_cells(st, i, cells, c_arr.ravel()[cells],
                                  d_sel.ravel()[cells], over=over)
        assert np.array_equal(caps.ravel()[cells], caps_c), name
        for f in cells[:6]:
            j, k = int(f) // K, int(f) % K
            c = int(c_arr[j, k])
            assert max_commit(st, i, j, k, c) \
                == max_commit(st, i, j, k, c, over=over), (name, i, j, k)
