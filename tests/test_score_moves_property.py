"""Hypothesis property tests for `score_moves_batch` (PR-3).

On ANY randomly generated instance and its GH construction state, the
scored move matrix must agree with sequential `_try_move`-style probing:
same admissible destination set, same commit caps, same post-move
objectives at 1e-9 — and the lazy `improve_below` path must be exactly the
full scan filtered by the improvement bound.  The shared scalar oracle
lives in `tests/test_local_search_batched.py`, which also runs it
deterministically on the fixed instance suite (this file is skipped where
hypothesis is unavailable).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import agh, is_feasible, random_instance
from repro.core.gh import greedy_heuristic
from repro.core.mechanisms import score_moves_batch, state_objective

from test_local_search_batched import (assert_scores_match_probing,
                                       sources_of)


@settings(max_examples=12, deadline=None)
@given(st.integers(3, 8), st.integers(3, 6), st.integers(4, 10),
       st.integers(0, 10_000))
def test_score_moves_batch_matches_sequential_probing(I, J, K, seed):
    inst = random_instance(I, J, K, seed=seed)
    _, state = greedy_heuristic(inst)
    for (i, j, k) in sources_of(state)[:8]:
        assert_scores_match_probing(state, i, j, k)


@settings(max_examples=8, deadline=None)
@given(st.integers(3, 8), st.integers(3, 6), st.integers(4, 10),
       st.integers(0, 10_000))
def test_score_moves_batch_improve_below_filter(I, J, K, seed):
    """The lazy path (including its scalar-caps branch for few surviving
    candidates) reports exactly the full scan's admissible set
    intersected with the improvement bound."""
    inst = random_instance(I, J, K, seed=seed)
    _, state = greedy_heuristic(inst)
    obj = state_objective(state)
    for (i, j, k) in sources_of(state)[:6]:
        full = score_moves_batch(state, i, j, k)
        lazy = score_moves_batch(state, i, j, k, improve_below=obj - 1e-9)
        want = full.admissible & (full.obj_after < obj - 1e-9)
        assert np.array_equal(lazy.admissible, want)
        assert np.allclose(lazy.obj_after[want], full.obj_after[want],
                           atol=0, rtol=1e-12)


@settings(max_examples=6, deadline=None)
@given(st.integers(3, 8), st.integers(3, 6), st.integers(4, 10),
       st.integers(0, 10_000))
def test_batched_agh_feasible_on_random_instances(I, J, K, seed):
    inst = random_instance(I, J, K, seed=seed)
    sol = agh(inst)
    assert is_feasible(inst, sol, enforce_zeta=False)
    assert sol.u.max() <= 1.0 + 1e-9
