import numpy as np
import pytest


@pytest.fixture(scope="session")
def default_inst():
    from repro.core import default_instance
    return default_instance()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
