"""Perf smoke: the paper's sub-second claim must not silently regress.

The seed's pure-Python AGH took ~7.9 s on the (20,20,20) Table-6 instance;
the vectorized engine runs it in ~0.1 s.  The bound here is deliberately
generous (2 s) so the test only fires on an order-of-magnitude regression,
not on machine noise.  Kept fast enough to run in every tier-1 pass."""
import time

from repro.core import agh, gh, random_instance


def test_gh_subsecond_at_paper_scale():
    inst = random_instance(20, 20, 20, seed=0)
    t0 = time.perf_counter()
    gh(inst)
    assert time.perf_counter() - t0 < 0.5


def test_agh_subsecond_at_paper_scale():
    inst = random_instance(20, 20, 20, seed=0)
    t0 = time.perf_counter()
    sol = agh(inst)
    wall = time.perf_counter() - t0
    assert wall < 2.0, f"AGH took {wall:.2f}s on (20,20,20) — vectorized " \
        "engine regressed by an order of magnitude"
    assert sol.u.max() <= 1.0 + 1e-9
