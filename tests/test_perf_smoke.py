"""Perf smoke: the paper's sub-second claim must not silently regress.

The seed's pure-Python AGH took ~7.9 s on the (20,20,20) Table-6 instance;
the vectorized engine runs it in ~0.1 s.  The bound here is deliberately
generous (2 s) so the test only fires on an order-of-magnitude regression,
not on machine noise.  Kept fast enough to run in every tier-1 pass."""
import time

import numpy as np

from repro.core import agh, evaluate, gh, random_instance


def test_gh_subsecond_at_paper_scale():
    inst = random_instance(20, 20, 20, seed=0)
    t0 = time.perf_counter()
    gh(inst)
    assert time.perf_counter() - t0 < 0.5


def test_agh_subsecond_at_paper_scale():
    inst = random_instance(20, 20, 20, seed=0)
    t0 = time.perf_counter()
    sol = agh(inst)
    wall = time.perf_counter() - t0
    assert wall < 2.0, f"AGH took {wall:.2f}s on (20,20,20) — vectorized " \
        "engine regressed by an order of magnitude"
    assert sol.u.max() <= 1.0 + 1e-9


def test_batched_local_search_beats_reference_mode():
    """PR-3: the scored-matrix local search must stay measurably ahead of
    the reference first-improvement probe loop on the (30,30,20)
    beyond-paper instance.  Measured ~2x on a quiet box; the 1.2x bar
    only fires on a real regression of the batched engine."""
    from repro.core.agh import (_consolidate, _consolidate_batched,
                                _rank_inactive_targets, _relocate,
                                _relocate_batched)
    from repro.core.gh import _phase1, greedy_heuristic
    from repro.core.mechanisms import State, state_objective, state_snapshot

    inst = random_instance(30, 30, 20, seed=42)
    st0 = State.fresh(inst)
    _phase1(st0)
    p1 = state_snapshot(st0)
    order = np.argsort(-inst.lam)
    ranked = _rank_inactive_targets(inst)

    def run_batched():
        _, st = greedy_heuristic(inst, order=order, phase1_snapshot=p1)
        t0 = time.perf_counter()
        _relocate_batched(st, 3, False)
        _consolidate_batched(st, False)
        return time.perf_counter() - t0, state_objective(st)

    def run_reference():
        _, st = greedy_heuristic(inst, order=order, phase1_snapshot=p1)
        t0 = time.perf_counter()
        _relocate(st, 3, ranked, False)
        _consolidate(st, False)
        return time.perf_counter() - t0, state_objective(st)

    run_batched(), run_reference()          # warm both paths
    tb, ob = min(run_batched() for _ in range(3))
    tr, orf = min(run_reference() for _ in range(3))
    assert ob <= orf + 1e-9, f"batched LS worse: {ob} vs {orf}"
    assert tr / tb > 1.2, \
        f"batched local search only {tr / tb:.2f}x over reference mode"


def test_agh_subsecond_beyond_paper_scale():
    """PR-3 acceptance: the batched engine completes the beyond-paper
    (40,40,30) Table-6 size well under a second (measured ~0.3-0.4 s; the
    2 s bar only fires on an order-of-magnitude regression)."""
    inst = random_instance(40, 40, 30, seed=42)
    t0 = time.perf_counter()
    sol = agh(inst)
    wall = time.perf_counter() - t0
    assert wall < 2.0, f"AGH took {wall:.2f}s on (40,40,30)"
    assert sol.u.max() <= 1.0 + 1e-9


def test_agh_paper_scale_100_80_40_wall():
    """PR-4 acceptance size: the incremental engine runs (100,80,40)
    sequentially in ~1 s on the 2-core reference box (PR-3 engine:
    ~1.7-1.8 s).  The 6 s bar only fires on a multi-x regression of the
    incremental local search, not on CI machine noise."""
    inst = random_instance(100, 80, 40, seed=42)
    t0 = time.perf_counter()
    sol = agh(inst, workers=0)
    wall = time.perf_counter() - t0
    assert wall < 6.0, f"AGH took {wall:.2f}s on (100,80,40)"
    assert sol.u.max() <= 1.0 + 1e-9


def test_warm_replan_matches_cold_quality_at_lower_wall():
    """ISSUE-5 acceptance: warm-started `PlanSession.replan()` on a ±15%
    drifted (100,80,40) workload achieves objective <= cold AGH at
    measurably lower wall time.  Measured on the 2-core reference box:
    warm ~0.45-0.6 s vs cold ~1.0-1.3 s (>= 2x) with the warm protocol
    recovering the cold multi-start's exact objective (the replayed
    winning ordering lands in the same basin).  The bars below only fire
    on a real regression: quality must never be worse, and the warm path
    must keep a >= 1.3x advantage."""
    from repro.planner import PlanOptions, PlanSession, plan

    inst = random_instance(100, 80, 40, seed=42)
    drift = np.random.default_rng(7).uniform(0.85, 1.15, inst.I)
    drifted = inst.with_lam(inst.lam * drift)

    t0 = time.perf_counter()
    cold = plan("agh", instance=drifted, options=PlanOptions(workers=0))
    t_cold = time.perf_counter() - t0

    ses = PlanSession(options=PlanOptions(workers=0))
    ses.plan(instance=inst)
    t0 = time.perf_counter()
    warm = ses.replan(instance=drifted)
    t_warm = time.perf_counter() - t0

    assert warm.objective <= cold.objective + 1e-9, \
        f"warm replan worse than cold: {warm.objective} > {cold.objective}"
    assert warm.diagnostics["warm_started"]
    ratio = t_cold / max(t_warm, 1e-9)
    assert ratio > 1.3, \
        f"warm replan only {ratio:.2f}x over cold AGH (want >= 1.3x)"


def test_repair_subsecond_at_fleet_scale():
    """ISSUE-8 acceptance: warm `PlanSession.repair` after a supply fault
    on the (100,80,40) fleet completes well under a second on the 2-core
    reference box (measured ~0.1-0.2 s vs ~1 s for a cold re-solve) —
    the eviction + one-pass re-route must stay an order of magnitude
    cheaper than replanning from scratch."""
    import dataclasses

    from repro.planner import PlanOptions, PlanSession

    inst = random_instance(100, 80, 40, seed=42)
    sess = PlanSession(options=PlanOptions(workers=0))
    res0 = sess.plan(instance=inst)
    y_tier = res0.solution.y.sum(axis=0)
    busiest = int(np.argmax(y_tier))
    caps = np.ceil(1.5 * y_tier) + 4
    caps[busiest] = 0.0
    faulted = dataclasses.replace(inst, avail_gpus=caps)

    t0 = time.perf_counter()
    rep = sess.repair(instance=faulted)
    wall = time.perf_counter() - t0
    assert wall < 1.0, f"warm repair took {wall:.2f}s on (100,80,40)"
    d = rep.diagnostics["repair"]
    assert d["warm"] and d["evicted"]
    assert rep.solution.y[:, busiest].sum() == 0.0


def test_batched_evaluate_beats_seed_loop():
    """The pattern-reuse Stage-2 engine must stay well ahead of the seed's
    per-scenario protocol (perturbed instance rebuild + from-scratch LP
    assembly, frozen in `stage2_lp_ref`).  Measured ~17x on the (20,20,20)
    acceptance workload; the 3x bar here only fires on a real regression."""
    from repro.core._scalar_ref import stage2_lp_ref
    from repro.core.stage2 import stage2_cost

    inst = random_instance(20, 20, 20, seed=0)
    deploy = gh(inst)
    S = 30
    t0 = time.perf_counter()
    res = evaluate(inst, deploy, S=S, seed=3)
    fast = time.perf_counter() - t0

    rng = np.random.default_rng(3)
    costs = np.zeros(S)
    t0 = time.perf_counter()
    for s in range(S):
        scen = inst.perturbed(rng, d_infl=0.15, e_infl=0.10, lam_pm=0.20)
        sol, _ = stage2_lp_ref(scen, deploy)
        costs[s] = stage2_cost(scen, sol)
    slow = time.perf_counter() - t0

    assert np.allclose(res.per_scenario_cost, costs, atol=1e-6)
    assert slow / fast > 3.0, \
        f"batched evaluate only {slow / fast:.1f}x over the seed loop"
