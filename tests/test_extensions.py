"""Queueing extension + closed-loop simulator (paper future-work items)."""
import numpy as np

from repro.core import agh, default_instance
from repro.core.queueing import (queueing_delay, slo_attainment_with_queueing,
                                 utilization, with_queueing_margin)
from repro.core.solution import proc_delay
from repro.serving.simulator import simulate


def test_queueing_delay_dominates_load_free(default_inst):
    sol = agh(default_inst)
    d0 = proc_delay(default_inst, sol)
    dq = queueing_delay(default_inst, sol)
    assert np.all(dq >= d0 - 1e-9)
    rho = utilization(default_inst, sol)
    assert np.all(rho >= 0) and np.all(rho < 1)


def test_margin_planning_survives_queueing(default_inst):
    """A plan built with rho_max margin must satisfy the ORIGINAL SLOs
    even after the M/G/1-PS inflation."""
    sol_m = agh(with_queueing_margin(default_inst, rho_max=0.5))
    q = slo_attainment_with_queueing(default_inst, sol_m)
    assert q["violations_queueing"] == 0
    assert q["max_rho"] <= 0.5 + 1e-6


def test_margin_costs_coverage_or_budget(default_inst):
    """At a fixed budget, headroom is paid for in coverage (or cost)."""
    base = agh(default_inst)
    margin = agh(with_queueing_margin(default_inst, rho_max=0.5))
    # either some demand is dropped or provisioning is at least as large
    from repro.core import provisioning_cost
    assert (margin.u.max() > base.u.max() + 1e-6
            or provisioning_cost(default_inst, margin)
            >= provisioning_cost(default_inst, base) - 1e-6)


def test_simulator_serves_and_measures(default_inst):
    sol = agh(default_inst)
    st = simulate(default_inst, sol, horizon_s=60.0, rate_scale=0.01, seed=0)
    assert st.n_served > 0
    served_types = ~np.isnan(st.per_type_ttft_p50)
    assert served_types.any()
    # TTFT <= end-to-end wherever measured
    ok = served_types & ~np.isnan(st.per_type_e2e_p95)
    assert np.all(st.per_type_ttft_p50[ok] <= st.per_type_e2e_p95[ok] + 1e-9)
    # attainment in [0, 1]
    assert np.all((st.per_type_slo_attain >= 0)
                  & (st.per_type_slo_attain <= 1))


def test_simulator_margin_plan_attains_more():
    """Closed loop: the queueing-aware plan's simulated SLO attainment
    must beat the load-free plan's on the tightest types."""
    inst = default_instance(budget=150.0)
    base = agh(default_instance())
    margin = agh(with_queueing_margin(inst, rho_max=0.5))
    st0 = simulate(default_instance(), base, horizon_s=240.0,
                   rate_scale=0.02, seed=1)
    st1 = simulate(inst, margin, horizon_s=240.0, rate_scale=0.02, seed=1)
    m0 = np.nanmean(st0.per_type_slo_attain)
    m1 = np.nanmean(st1.per_type_slo_attain)
    assert m1 >= m0 - 0.05, (m0, m1)


def test_carbon_accounting_and_pricing(default_inst):
    from repro.core.carbon import carbon_priced, carbon_rates, emissions
    rates = carbon_rates(default_inst)
    assert rates.shape == (default_inst.K,)
    assert np.all(rates > 0)
    sol = agh(default_inst)
    em = emissions(default_inst, sol)
    assert em > 0
    # carbon-priced instance raises every rental price
    ci = carbon_priced(default_inst, carbon_price=1.0)
    assert np.all(ci.p_c > default_inst.p_c)
    # planning against it never increases emissions at equal-or-better
    # feasibility (weak check: emissions do not grow)
    sol_c = agh(ci)
    assert emissions(default_inst, sol_c) <= em + 1e-9
