"""Property-based tests (hypothesis) on the allocator's invariants:
for ANY randomly generated instance, GH/AGH output must satisfy the
coupled constraint system they claim to preserve."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (agh, feasibility, gh, is_feasible, objective,
                        random_instance)
from repro.core.mechanisms import State, commit, m1_select, max_commit


@st.composite
def instances(draw):
    I = draw(st.integers(2, 6))
    J = draw(st.integers(2, 5))
    K = draw(st.integers(2, 6))
    seed = draw(st.integers(0, 10_000))
    budget = draw(st.floats(30.0, 400.0))
    return random_instance(I, J, K, seed=seed, budget=budget)


@settings(max_examples=25, deadline=None)
@given(instances())
def test_gh_always_feasible(inst):
    """THE paper claim: constraint-aware construction never emits an
    infeasible allocation (unmet demand is allowed; constraint violation
    is not)."""
    sol = gh(inst)
    v = feasibility(inst, sol, enforce_zeta=False)
    for name, val in v.items():
        assert val <= 1e-4, (name, val, inst.I, inst.J, inst.K)


@settings(max_examples=10, deadline=None)
@given(instances())
def test_agh_feasible_and_no_worse(inst):
    g = gh(inst)
    a = agh(inst, R=2, patience=3)
    assert is_feasible(inst, a, enforce_zeta=False)
    assert objective(inst, a) <= objective(inst, g) + 1e-6


@settings(max_examples=25, deadline=None)
@given(instances(), st.integers(0, 5))
def test_m1_selection_is_feasible_and_cheapest(inst, i_raw):
    i = i_raw % inst.I
    for j in range(inst.J):
        for k in range(inst.K):
            c = m1_select(inst, i, j, k)
            if c is None:
                continue
            n, m = inst.configs[c]
            assert inst.B_eff[j, k] / (n * m) <= inst.C_gpu[k] + 1e-9
            assert inst.D_cfg[i, j, k, c] <= inst.Delta[i] + 1e-9
            # minimality: no strictly smaller nm is feasible
            for c2, (n2, m2) in enumerate(inst.configs):
                if n2 * m2 < n * m:
                    fits = (inst.B_eff[j, k] / (n2 * m2) <= inst.C_gpu[k]
                            and inst.D_cfg[i, j, k, c2] <= inst.Delta[i])
                    assert not fits


@settings(max_examples=15, deadline=None)
@given(instances())
def test_max_commit_never_overcommits(inst):
    """Committing exactly max_commit must keep the running state feasible."""
    st_ = State.fresh(inst)
    order = np.argsort(-inst.lam)
    for i in order[: min(3, inst.I)]:
        i = int(i)
        for j in range(inst.J):
            for k in range(inst.K):
                if st_.q[j, k] > 0.5:
                    # active pair: reuse its config (GH's Phase-2 rule)
                    c = int(st_.cfg[j, k])
                    if inst.D_cfg[i, j, k, c] > inst.Delta[i]:
                        continue
                else:
                    c = m1_select(inst, i, j, k)
                    if c is None:
                        continue
                frac = min(st_.r_rem[i], max_commit(st_, i, j, k, c))
                if frac <= 1e-9:
                    continue
                commit(st_, i, j, k, c, frac)
                break
            else:
                continue
            break
    from repro.core.gh import greedy_heuristic
    # state-level invariants
    assert st_.spend <= inst.delta + 1e-6
    assert np.all(st_.r_rem >= -1e-9)
    assert np.all(st_.E_used <= inst.eps + 1e-9)
    assert np.all(st_.D_used <= inst.Delta + 1e-9)
