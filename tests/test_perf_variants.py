"""Beyond-paper optimization variants (§Perf): numerical correctness on
CPU (the dry-run measures their distributed effect)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decoder
from repro.models.moe import moe_apply, moe_params

RNG = jax.random.PRNGKey(0)


def test_w8a8_moe_close_to_bf16():
    cfg = get_config("kimi-k2-1t-a32b").smoke()
    cfg32 = dataclasses.replace(cfg, dtype="float32")
    p = moe_params(RNG, cfg32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    ref = moe_apply(p, cfg32, x)
    cfg_q = dataclasses.replace(cfg32, moe_w8a8=True)
    pq = moe_params(RNG, cfg_q)           # same rng -> same pre-quant weights
    out = moe_apply(pq, cfg_q, x)
    # INT8 quantization error should be small but non-zero (mu > 1 in the
    # paper's terms).
    err = float(jnp.abs(out - ref).max())
    rel = err / float(jnp.abs(ref).max())
    assert rel < 0.15, rel
    assert err > 0.0


def test_seqshard_flag_is_noop_on_single_device():
    """With no mesh, the constraint cascade falls through and the
    unchunked attention must equal the streaming-chunked baseline."""
    cfg = get_config("qwen2-1.5b").smoke()
    cfg_on = dataclasses.replace(cfg, seq_shard_attention=True)
    params = decoder.init_params(RNG, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                              cfg.vocab_size)
    lg0, _ = decoder.prefill(params, cfg, toks, max_len=40)
    lg1, _ = decoder.prefill(params, cfg_on, toks, max_len=40)
    np.testing.assert_allclose(np.asarray(lg0, np.float32),
                               np.asarray(lg1, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_unchunked_equals_chunked_attention():
    from repro.models.layers import attention, attention_unchunked
    rng = np.random.default_rng(0)
    B, T, H, KV, hd = 2, 384, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    pos = jnp.arange(T)
    a = attention(q, k, v, pos, pos, block_q=128, block_k=128)
    b = attention_unchunked(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
    aw = attention(q, k, v, pos, pos, window=100, block_q=128, block_k=128)
    bw = attention_unchunked(q, k, v, pos, pos, window=100)
    np.testing.assert_allclose(np.asarray(aw), np.asarray(bw), atol=2e-5)
