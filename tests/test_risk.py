"""repro.risk — batched Stage-2 solver + CVaR evaluation contracts.

The acceptance spine: the pdhg engine (anchor-basis Woodbury warm starts
+ restarted PDHG + counted exact fallback) must reproduce the exact
HiGHS oracle per scenario to rtol 1e-5, nominal AND stressed — the
stressed case pins the wide `_SHAPE_CLASSES` tier (15-16 active
delay/error rows per basis), which degenerated to per-scenario exact
solves before the shape classes existed.

Also pinned here: the scenario-stream chunking bit-identity that
`risk_evaluate` leans on (`perturbed_chunks` == one-shot
`perturbed_batch`), the `coefficient_batch` == `_coefficients` row
identity both engines consume, CVaR against a hand-computed value, the
report JSON round trip, the planner `risk=` hook, and the invariant-lint
scopes covering `src/repro/risk/`.
"""
import numpy as np
import pytest

from repro.core import agh, gh, random_instance
from repro.core.instance import ScenarioBatch
from repro.core.stage2 import HAVE_HIGHSPY, Stage2System
from repro.risk import RiskReport, rank_deployments, risk_evaluate
from repro.risk.api import PROTOCOL
from repro.risk.metrics import var_cvar
from repro.risk.solver_exact import ExactChunkSolver

jax = pytest.importorskip("jax")
from repro.risk.solver import BatchedStage2Solver  # noqa: E402

RTOL = 1e-5    # the pdhg-vs-oracle acceptance contract


@pytest.fixture(scope="module")
def inst():
    return random_instance(10, 8, 8, seed=7)


@pytest.fixture(scope="module")
def deploy(inst):
    return gh(inst)


def _batch(inst, S, seed=None):
    rng = np.random.default_rng(PROTOCOL["seed"] if seed is None else seed)
    return inst.perturbed_batch(rng, S, d_infl=PROTOCOL["d_infl"],
                                e_infl=PROTOCOL["e_infl"],
                                lam_pm=PROTOCOL["lam_pm"])


# -- pdhg engine vs the exact oracle ------------------------------------

def test_pdhg_matches_oracle_per_scenario(inst, deploy):
    S = 300
    batch = _batch(inst, S)
    system = Stage2System(inst, deploy)
    out_pd = BatchedStage2Solver(system).solve_scenarios(batch)
    out_ex = ExactChunkSolver(system).solve_scenarios(batch)
    np.testing.assert_allclose(out_pd.costs, out_ex.costs, rtol=RTOL)


def test_pdhg_matches_oracle_stressed_wide_bases():
    """1.5x stress activates 15-16 delay/error rows per optimal basis —
    only representable through the wide (q, eg) shape class.  Before the
    shape classes every anchor was rejected and the whole batch fell to
    per-scenario exact solves; anchors > 0 pins the fix."""
    big = random_instance(20, 20, 20, seed=42)
    sinst = big.stressed(1.5)
    dep = agh(big)
    S = 160
    batch = _batch(sinst, S)
    system = Stage2System(sinst, dep)
    solver = BatchedStage2Solver(system)
    out_pd = solver.solve_scenarios(batch)
    out_ex = ExactChunkSolver(system).solve_scenarios(batch)
    np.testing.assert_allclose(out_pd.costs, out_ex.costs, rtol=RTOL)
    assert len(solver.anchors) > 0
    assert solver.diagnostics["n_anchor0"] > 0


def test_forced_pdhg_path_and_diagnostics_accounting(inst, deploy):
    """max_anchors=0 freezes the anchor set at the seed anchor, forcing
    every miss through restarted PDHG (phase 2) — and every scenario must
    be accounted for in exactly one diagnostics bucket."""
    S = 120
    batch = _batch(inst, S, seed=5)
    solver = BatchedStage2Solver(Stage2System(inst, deploy), max_anchors=0)
    out = solver.solve_scenarios(batch)
    d = solver.diagnostics
    assert d["n_scenarios"] == S
    assert (d["n_anchor0"] + d["n_harvest_exact"] + d["n_pdhg"]
            + d["n_fallback_exact"]) == S
    assert d["n_pdhg"] + d["n_fallback_exact"] > 0
    out_ex = ExactChunkSolver(Stage2System(inst, deploy)) \
        .solve_scenarios(batch)
    np.testing.assert_allclose(out.costs, out_ex.costs, rtol=RTOL)


# -- metrics ------------------------------------------------------------

def test_cvar_hand_computed():
    """Rockafellar-Uryasev on costs 0..99 at alpha=0.9: VaR = 89.1 (the
    interpolated 0.9-quantile), tail excess sum_{c=90..99}(c - 89.1) = 54
    => CVaR = 89.1 + 0.54/0.1 = 94.5."""
    costs = np.arange(100, dtype=float)
    var, cvar = var_cvar(costs, 0.90)
    assert var == pytest.approx(89.1)
    assert cvar == pytest.approx(94.5)
    # Coherence: CVaR dominates VaR dominates the mean, monotone in alpha.
    assert cvar >= var >= costs.mean()
    assert var_cvar(costs, 0.95)[1] >= cvar


# -- report / api -------------------------------------------------------

def test_risk_report_json_round_trip(inst, deploy):
    r = risk_evaluate(inst, deploy, S=64, engine="exact")
    r2 = RiskReport.from_json(r.to_json())
    assert r2.to_dict() == r.to_dict()
    s = r.summary()
    assert s["expected_cost"] == r.expected_cost
    assert s["cvar_0.95"] == r.cvar["0.95"]


def test_risk_evaluate_chunking_invariant(inst, deploy):
    """Chunk size is an implementation detail: same S, different chunk
    => bit-identical statistics (scenario stream + per-scenario solves
    are both chunk-invariant)."""
    r1 = risk_evaluate(inst, deploy, S=96, engine="exact", chunk=96)
    r2 = risk_evaluate(inst, deploy, S=96, engine="exact", chunk=32)
    assert r1.expected_cost == r2.expected_cost
    assert r1.cvar == r2.cvar
    assert r1.viol_quantiles == r2.viol_quantiles


def test_risk_evaluate_rejects_unknown_engine(inst, deploy):
    with pytest.raises(ValueError, match="unknown engine"):
        risk_evaluate(inst, deploy, S=8, engine="simplex")


def test_rank_deployments_stress_orderings(inst, deploy):
    plans = {"gh": deploy, "agh": agh(inst)}
    rk = rank_deployments(inst, plans, S=48, engine="exact", stress=1.5)
    assert sorted(rk["ranking_expected"]) == sorted(plans)
    assert sorted(rk["ranking_cvar"]) == sorted(plans)
    assert rk["agree"] == (rk["ranking_expected"] == rk["ranking_cvar"])
    assert set(rk["summaries"]) == set(plans)
    reports = rk["reports"]
    e = [reports[k].expected_cost for k in rk["ranking_expected"]]
    assert e == sorted(e)
    cv = [reports[k].cvar["0.95"] for k in rk["ranking_cvar"]]
    assert cv == sorted(cv)


def test_planner_risk_hook(inst):
    from repro.planner import PlanOptions, plan
    res = plan("gh", instance=inst,
               options=PlanOptions(risk={"S": 32, "engine": "exact"}))
    row = res.diagnostics["risk"]
    assert row["S"] == 32 and row["engine"] == "exact"
    assert row["expected_cost"] > 0
    base = plan("gh", instance=inst)
    assert "risk" not in base.diagnostics
    assert base.objective == res.objective


# -- chunking / coefficient bit-identities ------------------------------

def test_perturbed_chunks_bit_identical_to_one_shot(inst):
    """Satellite (c): chunked scenario generation == one-shot at large S,
    bit for bit, including across chunk boundaries."""
    S, chunk = 10_000, 4096
    kw = dict(d_infl=PROTOCOL["d_infl"], e_infl=PROTOCOL["e_infl"],
              lam_pm=PROTOCOL["lam_pm"])
    one = inst.perturbed_batch(np.random.default_rng(9), S, **kw)
    parts = list(inst.perturbed_chunks(np.random.default_rng(9), S,
                                       chunk=chunk, **kw))
    assert [p.S for p in parts] == [4096, 4096, 1808]
    for field in ("tau", "e_base", "lam"):
        cat = np.concatenate([getattr(p, field) for p in parts])
        assert np.array_equal(cat, getattr(one, field))
    # The row right AFTER a chunk boundary is the one-shot row `chunk`.
    assert np.array_equal(parts[1].tau[0], one.tau[chunk])
    assert np.array_equal(parts[1].e_base[0], one.e_base[chunk])
    assert np.array_equal(parts[1].lam[0], one.lam[chunk])


def test_coefficient_batch_bit_identical_to_scalar(inst, deploy):
    batch = _batch(inst, 16, seed=11)
    system = Stage2System(inst, deploy)
    vals, c = system.coefficient_batch(batch)
    for s in range(batch.S):
        v1, c1 = system._coefficients(batch.tau[s], batch.e_base[s],
                                      batch.lam[s])
        assert np.array_equal(vals[s, :system.nnz], v1)
        assert np.array_equal(c[s], c1)
    # The equality tail is the constant 1.0 in every scenario.
    assert np.array_equal(vals[:, system.nnz:],
                          np.ones((batch.S, system.nnz_all - system.nnz)))


# -- satellites: highspy gate, lint scopes ------------------------------

@pytest.mark.skipif(HAVE_HIGHSPY,
                    reason="highspy installed: warm start is available")
def test_warm_start_requires_highspy(inst, deploy):
    system = Stage2System(inst, deploy)
    with pytest.raises(RuntimeError, match="highspy"):
        system.solve_batch(ScenarioBatch(S=2), warm_start=True)


def test_lint_scopes_cover_risk_subsystem():
    """src/repro/risk/ is an f64 LP tier like the xla engine: the dtype
    narrowing ban and the jit-purity checker must both scope it."""
    from repro.analysis.lint.checkers.dtype import DtypeChecker
    from repro.analysis.lint.checkers.jit_purity import JitPurityChecker
    assert "repro/risk/" in DtypeChecker.scope
    assert "repro/risk/" in JitPurityChecker.scope
    assert "repro/risk/" in DtypeChecker._NARROW_SCOPE
