"""Equivalence of the vectorized allocation engine and the scalar seed path.

The acceptance bar for the vectorized engine (precomputed M1 tables, batched
M2 ranking, incremental State aggregates, delta-move local search) is
behavioral: on the seeded suite it must return the same solutions as the
frozen scalar reference in `repro.core._scalar_ref` — same active pairs and
configs, routing and objective within 1e-9.  In practice the two paths are
bit-identical on every instance below; the tolerances only allow for float
re-association in the incremental aggregates.
"""
import numpy as np
import pytest

from repro.core import (agh, default_instance, gh, greedy_heuristic,
                        is_feasible, objective, random_instance)
from repro.core import _scalar_ref as ref
from repro.core.mechanisms import m1_select, m3_upgrade, max_commit_batch


def _instances():
    return [
        ("default", default_instance()),
        ("random-6-6-10", random_instance(6, 6, 10, seed=1)),
        ("random-8-5-6", random_instance(8, 5, 6, seed=2)),
        ("random-10-10-10", random_instance(10, 10, 10, seed=3)),
        ("stressed-1.15", default_instance().stressed(1.15)),
        ("stressed-1.3", default_instance().stressed(1.3)),
        ("tight-budget", random_instance(6, 6, 10, seed=4, budget=40.0)),
    ]


def _assert_same_solution(inst, a, b, label):
    assert np.array_equal(a.q, b.q), f"{label}: active pairs differ"
    assert np.array_equal(a.w, b.w), f"{label}: configs differ"
    assert np.allclose(a.y, b.y, atol=0), f"{label}: GPU counts differ"
    assert np.allclose(a.x, b.x, atol=1e-9), f"{label}: routing differs"
    assert np.allclose(a.u, b.u, atol=1e-9), f"{label}: unmet differs"
    oa, ob = objective(inst, a), objective(inst, b)
    assert abs(oa - ob) <= 1e-9 * max(1.0, abs(ob)), (label, oa, ob)


@pytest.mark.parametrize("name,inst", _instances())
def test_m1_table_matches_scalar_scan(name, inst):
    """cfg_m1 must reproduce the scalar config scan cell-for-cell."""
    for i in range(inst.I):
        for j in range(inst.J):
            for k in range(inst.K):
                want = ref.m1_select_ref(inst, i, j, k)
                got = m1_select(inst, i, j, k)
                assert got == want, (name, i, j, k, got, want)


@pytest.mark.parametrize("name,inst", _instances())
def test_gh_matches_scalar_reference(name, inst):
    sol_ref, _ = ref.gh_scalar(inst)
    sol_vec = gh(inst)
    _assert_same_solution(inst, sol_vec, sol_ref, f"GH/{name}")
    assert is_feasible(inst, sol_vec, enforce_zeta=False)


@pytest.mark.parametrize("name,inst", _instances()[:4])
def test_gh_matches_scalar_reference_alt_orderings(name, inst):
    for order in (np.arange(inst.I), np.arange(inst.I)[::-1],
                  np.argsort(inst.phi)):
        sol_ref, _ = ref.gh_scalar(inst, order=order)
        sol_vec, _ = greedy_heuristic(inst, order=order)
        _assert_same_solution(inst, sol_vec, sol_ref,
                              f"GH/{name}/order={order[:3]}...")


@pytest.mark.parametrize("ablation", [frozenset({"no_m1"}),
                                      frozenset({"no_m2"}),
                                      frozenset({"no_m3"})])
def test_gh_ablations_match_scalar_reference(ablation):
    inst = default_instance()
    sol_ref, _ = ref.gh_scalar(inst, ablation=ablation)
    sol_vec, _ = greedy_heuristic(inst, ablation=ablation)
    _assert_same_solution(inst, sol_vec, sol_ref, f"GH/{set(ablation)}")


@pytest.mark.parametrize("name,inst", [
    ("default", default_instance()),
    ("random-5-4-6", random_instance(5, 4, 6, seed=2)),
    ("random-6-6-10", random_instance(6, 6, 10, seed=1)),
    ("stressed-1.15", default_instance().stressed(1.15)),
])
def test_agh_matches_scalar_reference(name, inst):
    """Full AGH pipeline (multi-start + relocate + consolidate) in
    `local_search="reference"` mode: the delta-move engine must land on
    the scalar reference's solution bit-for-bit."""
    sol_ref = ref.agh_scalar(inst)
    sol_vec = agh(inst, local_search="reference", validate=True)
    _assert_same_solution(inst, sol_vec, sol_ref, f"AGH/{name}")
    assert is_feasible(inst, sol_vec, enforce_zeta=False)


@pytest.mark.parametrize("name,inst", _instances()[:4])
def test_max_commit_batch_matches_scalar_reference(name, inst):
    """Batched (8c)-(8h) caps equal the scalar from-scratch computation on
    mid-construction states, cell for cell."""
    _, st = greedy_heuristic(inst)
    for i in range(inst.I):
        c_arr = np.where(st.q > 0.5, st.cfg, inst.cfg_m1[i])
        caps = max_commit_batch(st, i, c_arr)
        for j in range(inst.J):
            for k in range(inst.K):
                c = int(c_arr[j, k])
                if c < 0:
                    assert caps[j, k] == 0.0
                    continue
                want = ref.max_commit_ref(st, i, j, k, c)
                assert abs(caps[j, k] - want) <= 1e-9 * max(1.0, want), \
                    (name, i, j, k, caps[j, k], want)


def test_m3_upgrade_matches_scalar_reference():
    """M3 decisions agree on states reached during construction."""
    inst = default_instance()
    _, st = greedy_heuristic(inst)
    for i in range(inst.I):
        for j in range(inst.J):
            for k in range(inst.K):
                if st.q[j, k] <= 0.5:
                    continue
                assert m3_upgrade(st, i, j, k) == ref.m3_upgrade_ref(st, i, j, k)
