"""Clean twin of bad_jit_purity: static branching, device-side selects.

Mirrors the repo's pallas idiom: compile-time scalars arrive keyword-
only through a functools.partial at the pallas_call site, so branching
on them is trace-time constant folding, not tracer leakage.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, window: int, ch: int):
    T = x_ref.shape[0]
    if window > 0:                          # static kw-only param: fine
        for i in range(T // ch):            # shape-derived bound: fine
            o_ref[i * ch] = x_ref[i * ch]


def launch(x, window: int, ch: int):
    return pl.pallas_call(
        functools.partial(_kernel, window=window, ch=ch),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)


@functools.partial(jax.jit, static_argnames=("causal",))
def masked(x, causal):
    y = jnp.where(x > 0, x, -x)             # device-side select: fine
    if causal:                              # static_argnames: fine
        y = jnp.tril(y)
    return y
