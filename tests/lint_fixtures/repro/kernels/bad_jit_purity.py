"""RPR401/402/403: Python control flow on traced values."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def branch_on_tracer(x, thresh):
    if x.sum() > thresh:                    # RPR401: Python if on tracer
        return x * 2.0
    return x


@jax.jit
def host_escape(x):
    return float(x.sum()) + x.mean().item()     # RPR402 twice


@jax.jit
def data_dependent_loop(x, n):
    acc = jnp.zeros_like(x)
    for _ in range(n):                      # RPR403: traced loop bound
        acc = acc + x
    return acc


@functools.partial(jax.jit, static_argnames=("flip",))
def mixed(x, flip):
    y = jnp.where(x > 0, x, -x)
    sign = 1.0 if x.max() > 0 else -1.0     # RPR401: IfExp on tracer
    return y * sign if flip else y
