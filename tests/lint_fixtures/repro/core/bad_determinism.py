"""RPR201/202/203/204: nondeterminism in an engine path."""
import os
import random
import time

import numpy as np


def unseeded(n: int):
    return np.random.rand(n)                # RPR201: legacy global API


def stdlib_random() -> float:
    return random.random()                  # RPR202 (import is too)


def set_order(members: set) -> list:
    return list(members)                    # RPR203: arbitrary order out


def set_loop() -> float:
    total = 0.0
    for x in {1.0, 2.0}:                    # RPR203: bare set iteration
        total = total / 2 + x
    return total


def wallclock() -> float:
    return time.time()                      # RPR204: wall-clock read


def env_knob() -> str:
    return os.environ["REPRO_MODE"]         # RPR204: environment read
