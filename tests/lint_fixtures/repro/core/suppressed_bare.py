"""Bare suppression fixture: no reason -> RPR002 AND the finding stays."""


def masked_fill(members: set, flags) -> None:
    # repro-lint: ignore[RPR203]
    flags[list(members)] = True
