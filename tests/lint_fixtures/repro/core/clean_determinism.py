"""Clean twin of bad_determinism: seeded RNG, sorted sets, perf timing."""
import time

import numpy as np


def seeded(n: int, seed: int):
    rng = np.random.default_rng(seed)       # explicit seeded Generator
    return rng.random(n)


def set_order(members: set) -> list:
    return sorted(members)                  # order-insensitive consumer


def set_reductions(members: set) -> float:
    return float(sum(members)) + float(len(members)) + float(max(members))


def timing() -> float:
    return time.perf_counter()              # reporting clock: legal
