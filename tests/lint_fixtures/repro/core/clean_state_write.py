"""Clean twin of bad_state_write: declared mutators and read-only use."""
from repro.core.contracts import mutates
from repro.core.mechanisms import State, commit


@mutates("spend", "q")
def sanctioned(st: State, j: int, k: int) -> None:
    st.spend -= 1.0
    st.q[j, k] = 0.0


def read_only(st: State) -> float:
    covered = len(st.uncovered) == 0        # reads are always fine
    return float(st.spend) + float(covered)


def routed(st: State, i: int, j: int, k: int) -> None:
    commit(st, i, j, k, 0, 0.5)             # mutation via the mutator
