"""RPR102/RPR103: @mutates declaration out of sync with the body."""
from repro.core.contracts import mutates
from repro.core.mechanisms import State


@mutates("spend")
def undeclared_write(st: State) -> None:
    st.spend += 1.0
    st.r_rem[0] = 0.0           # RPR102: written but not declared


@mutates("spend", "kv_tok")
def unused_declaration(st: State) -> None:
    st.spend += 1.0             # RPR103: 'kv_tok' declared, never written
