"""Suppression round-trip fixture: a justified ignore silences RPR203."""


def masked_fill(members: set, flags) -> None:
    # repro-lint: ignore[RPR203] -- boolean-mask fill is order-free.
    flags[list(members)] = True


def same_line(members: set) -> list:
    return list(members)  # repro-lint: ignore[RPR203] -- sorted downstream.
