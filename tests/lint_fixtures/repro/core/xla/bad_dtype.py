"""RPR301/302/303: f32 leaks in the f64 xla engine tier."""
import jax
import jax.numpy as jnp
import numpy as np


def implicit_dtype(n: int):
    grid = jnp.zeros((n, n))                # RPR301: implicit f32
    idx = jnp.arange(n)                     # RPR301: implicit dtype
    return grid, idx


def narrowing(x):
    lossy = x.astype(jnp.float32)           # RPR302: f32 narrowing
    return lossy + np.float32(1.5)          # RPR302: np.float32 cast


@jax.jit
def _score(base, scale):
    return base * scale


def weak_literal(base):
    return _score(base, 0.5)                # RPR303: weak float literal
