"""Clean twin of bad_dtype: pinned dtypes everywhere."""
import jax
import jax.numpy as jnp


def explicit_dtype(n: int, base):
    grid = jnp.zeros((n, n), dtype=jnp.float64)
    mirror = jnp.zeros((n, n), base.dtype)          # positional slot
    idx = jnp.arange(n, dtype=jnp.int64)
    like = jnp.zeros_like(base)                     # inherits: exempt
    return grid, mirror, idx, like


def widen(x):
    return x.astype(jnp.float64)                    # widening is fine


@jax.jit
def _score(base, scale):
    return base * scale


def typed_scalar(base):
    return _score(base, jnp.float64(0.5))           # explicit dtype in
