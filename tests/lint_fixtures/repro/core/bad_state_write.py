"""RPR101: unsanctioned State field write outside a @mutates mutator."""
from repro.core.mechanisms import State


def sneaky_discount(st: State, j: int, k: int) -> None:
    st.spend -= 1.0             # RPR101: direct write, no @mutates
    st.q[j, k] = 0.0            # RPR101: subscript store
    st.uncovered.add(0)         # RPR101: mutating method call


def local_constructor(inst) -> None:
    st = State.fresh(inst)
    st.cfg[0, 0] = 3            # RPR101: tracked via constructor binding
