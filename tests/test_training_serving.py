"""Training loop, data pipeline, checkpointing, serving engine, rolling."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import decoder
from repro.training.data import DataConfig, PackedStream
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def test_loss_decreases_on_smoke_model(tmp_path):
    cfg = get_config("qwen2-0.5b").smoke()
    stream = PackedStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                     batch_size=4))
    opt = AdamWConfig(lr=3e-3, total_steps=30, warmup_steps=3)
    _, hist = train(cfg, opt, stream, 30, log_every=5)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first - 0.2, (first, last)


def test_data_pipeline_deterministic():
    c = DataConfig(vocab_size=1000, seq_len=128, batch_size=2, seed=5)
    s1, s2 = PackedStream(c), PackedStream(c)
    b1, b2 = s1.batch(7), s2.batch(7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch(8)["tokens"], b1["tokens"])
    # targets are next-token shifted
    assert np.array_equal(b1["targets"][:, :-1], b1["tokens"][:, 1:])


def test_checkpoint_roundtrip(tmp_path):
    from repro.training import checkpoint
    tree = dict(a=np.arange(5.0), b=(np.ones((2, 2)), np.zeros(3)),
                c=dict(d=np.float32(2.0)))
    checkpoint.save(str(tmp_path / "ck"), tree, meta=dict(step=3))
    got, meta = checkpoint.restore(str(tmp_path / "ck"))
    assert meta["step"] == 3
    assert np.array_equal(got["a"], tree["a"])
    assert np.array_equal(got["b"][0], tree["b"][0])
    assert got["c"]["d"] == 2.0


def test_engine_generates_batch():
    from repro.serving.engine import Engine, Request
    cfg = get_config("qwen2-0.5b").smoke()
    params = decoder.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=48, max_batch=4)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab_size, 8,
                                               ).astype(np.int32),
                    max_new_tokens=6) for i in range(3)]
    out = eng.generate(reqs)
    for r in out:
        assert len(r.output) == 6
        assert r.first_token_s is not None and r.done_s is not None
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_rolling_static_vs_replan(default_inst):
    """Short rolling replay: both variants produce finite costs and the
    keep-best replan never does worse on its own forecast."""
    from repro.core import agh, rolling
    from repro.core.trace import diurnal_multipliers
    mult = diurnal_multipliers("busy", seed=1, n_windows=12)
    path = np.outer(mult, default_inst.lam)
    planner = lambda inst: agh(inst, R=1, patience=2)
    r_static = rolling(default_inst, path, planner, replan_every=None)
    r_roll = rolling(default_inst, path, planner, replan_every=4)
    assert np.isfinite(r_static.total_cost) and np.isfinite(r_roll.total_cost)
    assert r_static.per_window_cost.shape == (12,)


def test_trace_stats():
    from repro.core.trace import diurnal_multipliers, peak_to_trough
    busy = diurnal_multipliers("busy", seed=7)
    vol = diurnal_multipliers("volatile", seed=7)
    assert abs(busy.mean() - 1.0) < 1e-6
    assert 6.0 < peak_to_trough(busy) < 20.0
    assert peak_to_trough(vol) > peak_to_trough(busy)
